"""Process-level failover tier: heartbeat coordinator over worker processes.

PR 7's serving tier survives *engine* failures inside one Python process;
this module covers the failure domain above it — a whole engine process
(one "host") dying, or the coordinator process itself.  A
:class:`ClusterCoordinator` supervises ``num_workers`` subprocess workers
(each owning one :class:`~.snn_engine.SNNStreamEngine`) over
length-prefixed JSON-frame pipes (``serve.wire``), and keeps the PR 7
contract one level up: **any schedule of worker kills plus one
coordinator kill matches the no-fault run prediction-for-prediction**,
with every lost-state window accounted in a
:class:`~.faults.FaultRecord`.

Four mechanisms compose:

**Heartbeat + deadline detection** — every RPC read runs under
``fault_cfg.heartbeat_deadline_s`` (the PR 7 chunk-deadline watchdog
across a process boundary): a worker that cannot produce its frame in
time is declared hung and killed; a closed pipe is a crash.  Idle
workers are pinged every ``heartbeat_interval_s`` so a crash never hides
behind an empty queue.

**Checkpoint shipping + evacuation** — every ``step`` reply carries the
worker's active lanes as wire-serialized chunk-boundary checkpoints
(``engine.checkpoint_lanes`` → :func:`~.wire.lane_to_wire`); the
coordinator's shadow copy is therefore always the current state (the
worker idles between lockstep RPCs, so no chunk commits unobserved).
When a worker dies, its shadow rows are adopted — least-loaded, with
garbage-collected weight versions replayed via ``WeightBank.ensure`` —
onto survivors, where they resume **bit-identically** (the
chunked==one-shot invariant makes a row a complete placement-independent
checkpoint).  Requests queued but never checkpointed restart from their
write-ahead pixels: a window is a pure function of
``(seed, request_id, pixels)``, so the restart is also bit-identical.

**Restart-and-readopt** — a dead worker is respawned (budget
``fault_cfg.max_respawns`` per slot), its ``WeightBank`` seeded at the
fleet's current version, the PR 7 promotion probe run (one chunk
dispatch must succeed before the slot re-enters routing), and the fresh
process re-admitted into ``load_score`` routing — itself an immediate
evacuation target for its predecessor's lanes.

**Write-ahead replicated ledger** — the coordinator appends one JSONL
line per accounting event (``serve.ledger``), with the ``submit`` line
(pixels included) written *before* routing; every worker replicates its
``result`` lines to its own per-host file before shipping them.  A
killed coordinator is therefore recoverable: :meth:`recover` folds all
ledger files back into ``results ∪ shed ∪ faulted`` (results win — a
worker may have durably computed an answer the coordinator never saw),
replays ledgered weight rollouts to the pre-crash version, and re-runs
the outstanding ids from their write-ahead pixels (with their original
SLO deadlines), so the partition invariant survives the coordinator's
own death.

Faults are injected deterministically (``serve.faults.FaultPlan``):
``worker_kill``/``worker_hang``/``coordinator_kill`` events fire on
coordinator **global rounds** — windowed ``[r, r]`` so an event fires in
exactly one worker incarnation, and a *recovered* coordinator suppresses
``coordinator_kill`` (the crash already happened; replaying it would
loop forever).

Workers are spawned as ``python -c '... _worker_main(sys.argv[1:])'
<read_fd> <write_fd>`` with both pipe ends inherited via ``pass_fds`` —
dedicated fds, so stray ``print``\\ s to stdout can never corrupt a
frame.
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import time
from dataclasses import dataclass, field
from glob import glob

import numpy as np

from ..core.snn import SNNConfig
from ..core.telemetry import (EngineLoad, engine_load_from_wire,
                              estimate_eta_steps, load_score)
from .faults import (REPRO_FAULT_PLAN_ENV, FaultPlan, FaultRecord,
                     FaultToleranceConfig)
from .ledger import Ledger, recover_accounting
from .router import ShedRecord
from .wire import (array_from_wire, array_to_wire, params_from_wire,
                   params_to_wire, planes_to_wire, plan_to_wire, read_msg,
                   result_from_wire, result_to_wire, snn_cfg_to_wire,
                   write_msg)

__all__ = ["ClusterCoordinator", "CoordinatorCrash", "WorkerDied"]

# fault_cfg_to_wire lives in wire; imported lazily in _spawn to keep the
# hot import list honest
_RPC_LONG_TIMEOUT_S = 300.0   # init/probe: jax import + first compile


class CoordinatorCrash(RuntimeError):
    """The coordinator's own injected death (``coordinator_kill``).

    Raised out of :meth:`ClusterCoordinator.step`/``run`` after every
    worker is killed — the caller recovers with
    :meth:`ClusterCoordinator.recover` against the same ``ledger_dir``.
    """


class WorkerDied(Exception):
    """Internal signal: an RPC to a worker failed (crash/hang/error)."""

    def __init__(self, reason: str, detail: str = ""):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason      # "crash" | "hang" | "error"
        self.detail = detail


@dataclass
class WorkerHandle:
    """Coordinator-side state of one worker process slot."""

    proc: subprocess.Popen
    rfd: int                      # read end (worker → coordinator)
    wfd: int                      # write end (coordinator → worker)
    alive: bool = True
    incarnation: int = 0          # respawn count of this slot
    pending: int = 0              # engine-reported outstanding work
    shadow: dict = field(default_factory=dict)   # rid -> wire lane row
    versions: set = field(default_factory=set)   # bank versions on worker
    load: EngineLoad | None = None
    last_contact: float = 0.0     # monotonic instant of the last reply


def _record_fields(cls) -> set:
    return {f.name for f in dataclasses.fields(cls)}


class ClusterCoordinator:
    """Tier coordinator over N per-host engine processes (module doc).

    The accounting surface mirrors :class:`~.router.SNNServingTier`:
    :attr:`results`, :attr:`shed`, :attr:`faulted` — together they
    exactly partition every submitted id, and now survive any process in
    the cluster dying.  Use as a context manager (or call
    :meth:`close`): worker processes are real and must be reaped.
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *,
                 num_workers: int = 2, lanes_per_worker: int = 4,
                 chunk_steps: int = 4, patience: int = 2, seed: int = 0,
                 backend: str | None = None,
                 fault_plan: FaultPlan | str | None = None,
                 fault_cfg: FaultToleranceConfig | None = None,
                 ledger_dir: str | None = None,
                 _recovered: bool = False):
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        if ledger_dir is None:
            raise ValueError(
                "ClusterCoordinator requires ledger_dir: the write-ahead "
                "accounting ledger is the crash-recovery contract, not an "
                "option")
        if isinstance(fault_plan, str):
            fault_plan = FaultPlan.from_spec(fault_plan)
        self.fault_plan = fault_plan
        self.fault_cfg = fault_cfg or FaultToleranceConfig()
        self.cfg = cfg
        self.seed = int(seed)
        self.backend = backend
        self.num_workers = int(num_workers)
        self.lanes_per_worker = int(lanes_per_worker)
        self.chunk_steps = int(chunk_steps)
        self.patience = int(patience)
        self.n_in = int(cfg.layer_sizes[0])
        self.ledger_dir = ledger_dir
        self._ledger = Ledger(os.path.join(ledger_dir, "coordinator.jsonl"))
        # recovered coordinators never replay their own death — the
        # ledger already recorded the first one (see module doc)
        self._suppress_coordinator_kill = bool(_recovered)
        self._crash_after_evacuations: int | None = None  # test hook

        self._version_planes: dict[int, tuple] = {
            0: tuple(layer["w_q"] for layer in params_q["layers"])}
        self._version_params: dict[int, dict] = {0: params_q}
        self._current_version = 0

        self.results: dict[int, object] = {}
        self.shed: dict[int, ShedRecord] = {}
        self.faulted: dict[int, FaultRecord] = {}
        self._pixels: dict[int, np.ndarray] = {}   # rid -> px until terminal
        self._assignment: dict[int, int] = {}      # rid -> worker slot
        self._submitted: set[int] = set()
        self._order: list[int] = []
        self._next_id = 0
        self.round = 0                             # global lockstep round
        self._respawns = [0] * self.num_workers
        self.stats = {"routed_per_worker": [0] * self.num_workers,
                      "workers_failed": 0, "respawned": 0, "evacuated": 0,
                      "requeued": 0, "shed_deadline": 0}
        self.workers: list[WorkerHandle] = [
            self._spawn(i) for i in range(self.num_workers)]

    # ---- process management ---------------------------------------------
    def _worker_ledger_path(self, idx: int) -> str:
        return os.path.join(self.ledger_dir, f"worker-{idx}.jsonl")

    def _spawn(self, idx: int, incarnation: int = 0) -> WorkerHandle:
        """Spawn + init + promotion-probe one worker slot.

        The handle comes back ``alive=False`` (and never enters routing)
        if any stage fails — spawning is itself fallible, and a slot that
        cannot pass the probe must not adopt anyone's lanes.
        """
        c2w_r, c2w_w = os.pipe()
        w2c_r, w2c_w = os.pipe()
        env = dict(os.environ)
        # the coordinator ships the plan explicitly over RPC; the env
        # spec must not double-arm an injector inside the worker
        env.pop(REPRO_FAULT_PLAN_ENV, None)
        src_root = os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))))
        env["PYTHONPATH"] = src_root + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        # -c import (not -m): runpy would import the package (whose
        # __init__ already imported this module) and then re-execute the
        # module body as __main__ — the classic double-import warning
        proc = subprocess.Popen(
            [sys.executable, "-c",
             "import sys; from repro.serve.cluster import _worker_main; "
             "sys.exit(_worker_main(sys.argv[1:]))",
             str(c2w_r), str(w2c_w)],
            pass_fds=(c2w_r, w2c_w), env=env, close_fds=True)
        os.close(c2w_r)
        os.close(w2c_w)
        h = WorkerHandle(proc=proc, rfd=w2c_r, wfd=c2w_w,
                         incarnation=incarnation,
                         versions={self._current_version},
                         load=self._cold_load(),
                         last_contact=time.monotonic())
        from .wire import fault_cfg_to_wire
        v = self._current_version
        try:
            self._rpc(h, {
                "op": "init", "worker_id": idx, "incarnation": incarnation,
                "snn_cfg": snn_cfg_to_wire(self.cfg),
                "params": params_to_wire(self._version_params[v]),
                "initial_weight_version": v,
                "lanes": self.lanes_per_worker,
                "chunk_steps": self.chunk_steps, "patience": self.patience,
                "seed": self.seed, "backend": self.backend,
                "fault_cfg": fault_cfg_to_wire(self.fault_cfg),
                "plan": plan_to_wire(self.fault_plan),
                "ledger_path": self._worker_ledger_path(idx),
            }, _RPC_LONG_TIMEOUT_S)
            # PR 7 promotion probe across the process boundary: one chunk
            # dispatch must succeed before the slot serves traffic
            self._rpc(h, {"op": "probe"}, _RPC_LONG_TIMEOUT_S)
        except WorkerDied:
            self._kill_worker(h)
        return h

    def _cold_load(self) -> EngineLoad:
        return EngineLoad(
            lanes_total=self.lanes_per_worker, lanes_busy=0, queue_depth=0,
            mean_service_steps=float(self.cfg.num_steps), retired_total=0,
            density_ewma=None)

    def _kill_worker(self, h: WorkerHandle) -> None:
        h.alive = False
        try:
            h.proc.kill()
        except Exception:
            pass
        try:
            h.proc.wait(timeout=10)
        except Exception:
            pass
        for fd in (h.rfd, h.wfd):
            try:
                os.close(fd)
            except OSError:
                pass

    def _rpc(self, h: WorkerHandle, msg: dict,
             timeout_s: float | None) -> dict:
        """One request/reply exchange under the heartbeat deadline —
        applied to both directions: a stalled worker whose pipe buffer
        filled up blocks the request frame itself, and must trip the
        same hang detection as an overdue reply."""
        try:
            write_msg(h.wfd, msg, timeout_s)
            rep = read_msg(h.rfd, timeout_s)
        except TimeoutError as e:
            raise WorkerDied("hang", str(e)) from None
        except (EOFError, OSError) as e:
            raise WorkerDied("crash", str(e)) from None
        if not rep.get("ok"):
            raise WorkerDied("error", str(rep.get("error", "")))
        h.last_contact = time.monotonic()
        if "versions" in rep:
            h.versions = {int(v) for v in rep["versions"]}
        return rep

    # ---- routing / intake -----------------------------------------------
    def _alive(self) -> list[int]:
        return [i for i, h in enumerate(self.workers) if h.alive]

    def _route_index(self) -> int | None:
        """Least-loaded live worker; lowest index breaks ties (the same
        deterministic spray order as the in-process tier)."""
        idxs = self._alive()
        if not idxs:
            return None
        return min((load_score(self.workers[i].load), i) for i in idxs)[1]

    def submit(self, pixels_u8, *, deadline_steps: int | None = None,
               request_id: int | None = None) -> int:
        """Admit one request; the submit ledger line (pixels included)
        precedes routing — write-ahead, so a coordinator crash can never
        lose an admitted request."""
        px = np.asarray(pixels_u8, np.uint8).reshape(self.n_in)
        if request_id is None:
            rid = self._next_id
        else:
            rid = int(request_id)
            if rid in self._submitted:
                raise ValueError(f"request id {rid} already in use")
        self._next_id = max(self._next_id, rid + 1)
        # deadline_steps rides the write-ahead record: a coordinator
        # crash must not quietly upgrade an SLO-bounded request into an
        # unbounded one on recovery re-dispatch
        self._ledger.append({"kind": "submit", "rid": rid,
                             "px": array_to_wire(px),
                             "deadline_steps": deadline_steps})
        self._submitted.add(rid)
        self._order.append(rid)
        self._pixels[rid] = px
        self._dispatch(rid, px, deadline_steps=deadline_steps)
        return rid

    def _dispatch(self, rid: int, px: np.ndarray, *,
                  deadline_steps: int | None = None,
                  drop_reason: str = "no_capacity",
                  drop_worker: int | None = None,
                  drop_detail: str = "") -> None:
        """Route one request to the least-loaded live worker (retrying
        past workers that die under the submit RPC itself)."""
        while True:
            idx = self._route_index()
            if idx is None:
                self._drop(rid, drop_reason, drop_worker,
                           detail=drop_detail or "no live worker")
                return
            h = self.workers[idx]
            if deadline_steps is not None:
                eta = estimate_eta_steps(h.load)
                if eta > deadline_steps:
                    self._shed(rid, eta, deadline_steps)
                    return
            try:
                rep = self._rpc(h, {"op": "submit", "rid": rid,
                                    "px": array_to_wire(px)},
                                self.fault_cfg.heartbeat_deadline_s)
            except WorkerDied as e:
                self._on_worker_death(idx, e, self.round)
                continue
            h.pending = int(rep.get("pending", h.pending + 1))
            if "load" in rep:   # keep the routing surface live, not stale
                h.load = engine_load_from_wire(rep["load"])
            self._assignment[rid] = idx
            self.stats["routed_per_worker"][idx] += 1
            return

    # ---- accounting (every path writes the ledger first-class) ----------
    def _shed(self, rid: int, eta: float, deadline: int) -> None:
        rec = ShedRecord(request_id=rid, reason="deadline",
                         priority="standard", priority_level=0,
                         deadline_steps=deadline, eta_steps=eta)
        self.shed[rid] = rec
        self.stats["shed_deadline"] += 1
        self._ledger.append({"kind": "shed", "rid": rid,
                             **dataclasses.asdict(rec)})
        self._pixels.pop(rid, None)
        self._assignment.pop(rid, None)

    def _drop(self, rid: int, reason: str, worker: int | None,
              detail: str = "") -> None:
        """The never-silent fault drop (tier ``_drop``, process edition)."""
        rec = FaultRecord(request_id=rid, reason=reason, engine=worker,
                          faults=0, replay_seed=self.seed + rid,
                          detail=detail)
        self.faulted[rid] = rec
        self._ledger.append({"kind": "fault", "rid": rid,
                             **dataclasses.asdict(rec)})
        self._pixels.pop(rid, None)
        self._assignment.pop(rid, None)

    def _record_result(self, rid: int, wire_rec: dict) -> None:
        if rid in self.results:
            return
        self.results[rid] = result_from_wire(wire_rec)
        self._ledger.append({"kind": "result", "rid": rid,
                             **result_to_wire(self.results[rid])})
        self._pixels.pop(rid, None)
        self._assignment.pop(rid, None)

    def outstanding(self) -> list[int]:
        """Submitted ids with no terminal record yet (submit order)."""
        terminal = (self.results.keys() | self.shed.keys()
                    | self.faulted.keys())
        return [rid for rid in self._order if rid not in terminal]

    @property
    def pending(self) -> int:
        return sum(h.pending for h in self.workers if h.alive)

    # ---- drive ----------------------------------------------------------
    def step(self) -> list[int]:
        """One global lockstep round; returns rids finished this round.

        The round number is the fault plan's process-event coordinate —
        it never resets across worker respawns, so a ``[r, r]``-windowed
        kill fires in exactly one incarnation.
        """
        r = self.round
        self.round += 1
        if (self.fault_plan is not None
                and not self._suppress_coordinator_kill
                and self.fault_plan.coordinator_kill(r)):
            self._crash(r)
        done: list[int] = []
        for idx in range(self.num_workers):
            h = self.workers[idx]
            if not h.alive:
                continue
            if h.pending <= 0:
                # idle heartbeat: a crash must not hide behind an empty
                # queue until traffic next lands there
                if (time.monotonic() - h.last_contact
                        >= self.fault_cfg.heartbeat_interval_s):
                    try:
                        rep = self._rpc(
                            h, {"op": "ping"},
                            self.fault_cfg.heartbeat_deadline_s)
                        h.load = engine_load_from_wire(rep["load"])
                    except WorkerDied as e:
                        self._on_worker_death(idx, e, r)
                continue
            try:
                rep = self._rpc(h, {"op": "step", "round": r},
                                self.fault_cfg.heartbeat_deadline_s)
            except WorkerDied as e:
                self._on_worker_death(idx, e, r)
                continue
            for w in rep["done"]:
                rid = int(w["request_id"])
                if rid not in self.results:
                    self._record_result(rid, w)
                    done.append(rid)
            h.shadow = {int(rid): row for rid, row in rep["checkpoint"]}
            h.load = engine_load_from_wire(rep["load"])
            h.pending = int(rep["pending"])
        return done

    def run(self, max_rounds: int | None = None) -> dict:
        """Drive lockstep rounds until every submitted id is terminal.

        Never silent: if the bounded loop ends with unaccounted ids the
        coordinator raises instead of returning a partial partition.
        """
        limit = max_rounds if max_rounds is not None else (
            (len(self.outstanding())
             + self.num_workers * self.lanes_per_worker)
            * (self.cfg.num_steps // max(1, self.chunk_steps) + 2)
            + 64 * self.num_workers + 16)
        for _ in range(limit):
            if not self.outstanding():
                break
            self.step()
        for idx in range(self.num_workers):
            h = self.workers[idx]
            if not h.alive:
                continue
            try:
                rep = self._rpc(h, {"op": "drain"},
                                max(30.0, self.fault_cfg.heartbeat_deadline_s))
            except WorkerDied as e:
                self._on_worker_death(idx, e, self.round)
                continue
            for w in rep["done"]:
                rid = int(w["request_id"])
                if rid not in self.results:
                    self._record_result(rid, w)
        left = self.outstanding()
        if left:
            raise RuntimeError(
                f"cluster run ended with unaccounted requests {left} — "
                f"the results ∪ shed ∪ faulted partition is incomplete")
        return dict(self.results)

    # ---- failover --------------------------------------------------------
    def _crash(self, rnd: int):
        """Injected coordinator death: every worker dies with it (the
        simulated host loss), the ledger handle closes mid-stream, and
        :class:`CoordinatorCrash` propagates to the harness — which
        recovers via :meth:`recover` against the same ``ledger_dir``."""
        for h in self.workers:
            if h.alive:
                self._kill_worker(h)
        self._ledger.close()
        raise CoordinatorCrash(
            f"coordinator killed at round {rnd} (injected fault plan)")

    def _on_worker_death(self, idx: int, died: WorkerDied,
                         rnd: int) -> None:
        """Worker failover: kill, respawn-and-readopt, evacuate, requeue.

        Respawn runs FIRST so the replacement slot is itself an adoption
        target for its predecessor's lanes.  ``state_lost`` kill events
        discard the shipped checkpoint (the injected analogue of a host
        dying with its state unrecoverable) — those windows become
        ``FaultRecord("state_lost")``, never silent drops.
        """
        h = self.workers[idx]
        detail = (f"worker {idx} (incarnation {h.incarnation}) "
                  f"{died.reason} at round {rnd}: {died.detail}")
        shadow = dict(h.shadow)
        h.shadow = {}
        self._kill_worker(h)
        self.stats["workers_failed"] += 1
        ev = (self.fault_plan.worker_kill(idx, rnd)
              if self.fault_plan is not None else None)
        state_lost = bool(ev is not None and ev.state_lost)
        if self._respawns[idx] < self.fault_cfg.max_respawns:
            self._respawns[idx] += 1
            nh = self._spawn(idx, incarnation=h.incarnation + 1)
            self.workers[idx] = nh
            if nh.alive:
                self.stats["respawned"] += 1
        # snapshot the queued set BEFORE evacuating: a shadow row adopted
        # onto the RESPAWNED same slot leaves _assignment[rid] == idx, and
        # re-submitting an adopted rid would (rightly) be rejected
        queued = sorted(rid for rid, w in self._assignment.items()
                        if w == idx and rid not in shadow)
        for rid in sorted(shadow):
            if (rid in self.results or rid in self.faulted
                    or rid in self.shed):
                continue
            if state_lost:
                self._drop(rid, "state_lost", idx, detail=detail)
            else:
                self._evacuate(rid, shadow[rid], idx, detail, rnd)
        for rid in queued:
            if (rid in self.results or rid in self.faulted
                    or rid in self.shed):
                self._assignment.pop(rid, None)
                continue
            # queued on the dead worker, never checkpointed: replay the
            # whole window from its write-ahead pixels — pure in
            # (seed, rid, pixels), so bit-identical to the lost attempt
            self._assignment.pop(rid, None)
            self._dispatch(rid, self._pixels[rid],
                           drop_reason="engine_lost", drop_worker=idx,
                           drop_detail=detail)
            if rid in self._assignment:
                self.stats["requeued"] += 1

    def _evacuate(self, rid: int, row: dict, dead_idx: int, detail: str,
                  rnd: int) -> None:
        """Adopt one shadow checkpoint onto a live worker, replaying its
        (possibly garbage-collected) weight version via ``ensure``."""
        while True:
            tgt = self._route_index()
            if tgt is None:
                self._drop(rid, "engine_lost", dead_idx, detail=detail)
                return
            th = self.workers[tgt]
            ver = int(array_from_wire(row["leaves"]["weight_version"]))
            try:
                if ver not in th.versions:
                    self._rpc(th, {
                        "op": "ensure_version", "version": ver,
                        "planes": planes_to_wire(self._version_planes[ver]),
                    }, self.fault_cfg.heartbeat_deadline_s)
                    th.versions.add(ver)
                rep = self._rpc(th, {"op": "adopt", "rid": rid, "row": row},
                                self.fault_cfg.heartbeat_deadline_s)
            except WorkerDied as e:
                self._on_worker_death(tgt, e, rnd)
                continue
            self._assignment[rid] = tgt
            th.pending = int(rep.get("pending", th.pending + 1))
            if "load" in rep:
                th.load = engine_load_from_wire(rep["load"])
            th.shadow[rid] = row   # the checkpoint now lives on tgt
            self.stats["evacuated"] += 1
            if self._crash_after_evacuations is not None:
                self._crash_after_evacuations -= 1
                if self._crash_after_evacuations <= 0:
                    self._crash(rnd)
            return

    # ---- weight rollout --------------------------------------------------
    def begin_rollout(self, params_q: dict, *, _replay: bool = False) -> int:
        """Broadcast new packed planes to every live worker, zero-drain
        (the tier's ``begin_rollout`` over RPC; respawned workers seed at
        the fleet's current version, older in-flight versions replay on
        demand during evacuation).

        The rollout is **ledgered** (``kind="rollout"``, params included
        — they are wire-serializable by construction) so a recovered
        coordinator replays the fleet up to the pre-crash weight version
        before re-running outstanding ids, instead of silently
        recomputing them against version-0 weights.  ``_replay`` marks
        that recovery path: it must not re-append the record, or every
        recovery would double the rollout history.
        """
        wire_params = params_to_wire(params_q)
        versions = set()
        for idx in range(self.num_workers):
            h = self.workers[idx]
            if not h.alive:
                continue
            try:
                rep = self._rpc(h, {"op": "begin_rollout",
                                    "params": wire_params},
                                _RPC_LONG_TIMEOUT_S)
            except WorkerDied as e:
                self._on_worker_death(idx, e, self.round)
                continue
            versions.add(int(rep["version"]))
            h.versions.add(int(rep["version"]))
        if not versions:
            raise RuntimeError(
                "begin_rollout: no live worker accepted the rollout — "
                "the fleet is dead; recover() or respawn before rolling "
                "weights")
        if len(versions) != 1:
            raise RuntimeError(
                f"begin_rollout: workers out of lockstep — the fleet "
                f"reported versions {sorted(versions)}; refusing to pick "
                f"one (a respawn raced the broadcast)")
        v = versions.pop()
        self._version_planes[v] = tuple(
            layer["w_q"] for layer in params_q["layers"])
        self._version_params[v] = params_q
        self._current_version = v
        if not _replay:
            self._ledger.append({"kind": "rollout", "version": v,
                                 "params": wire_params})
        return v

    # ---- recovery --------------------------------------------------------
    @classmethod
    def recover(cls, params_q: dict, cfg: SNNConfig, *, ledger_dir: str,
                **kw) -> "ClusterCoordinator":
        """Rebuild a coordinator from the ledgers after its own death.

        Folds every host's JSONL file back into the three accounting
        maps (``result`` beats ``shed``/``fault`` per id — a worker's
        replicated line proves the answer was computed), replays the
        ledgered weight rollouts so the fresh fleet sits at the
        pre-crash version, then re-runs the outstanding ids from their
        write-ahead pixels in submit order — each with its original
        ``deadline_steps``, so an SLO-bounded request stays bounded
        across the crash.  No new ``submit`` lines are written (they are
        already durable) and ``coordinator_kill`` is suppressed — the
        recovered instance must not replay its own death.
        """
        co = cls(params_q, cfg, ledger_dir=ledger_dir, _recovered=True,
                 **kw)
        paths = ([co._ledger.path]
                 + sorted(glob(os.path.join(ledger_dir, "worker-*.jsonl"))))
        acc = recover_accounting(paths)
        shed_f, fault_f = _record_fields(ShedRecord), _record_fields(
            FaultRecord)
        for rid, rec in acc["results"].items():
            co.results[int(rid)] = result_from_wire(rec)
        for rid, rec in acc["shed"].items():
            co.shed[int(rid)] = ShedRecord(
                **{k: v for k, v in rec.items() if k in shed_f})
        for rid, rec in acc["faulted"].items():
            co.faulted[int(rid)] = FaultRecord(
                **{k: v for k, v in rec.items() if k in fault_f})
        for rec in acc["rollouts"]:
            co.begin_rollout(params_from_wire(rec["params"]), _replay=True)
        submit_recs = dict(acc["submitted"])
        co._order = [int(rid) for rid, _ in acc["submitted"]]
        co._submitted = set(co._order)
        co._next_id = max(co._order, default=-1) + 1
        for rid in acc["outstanding"]:
            rec = submit_recs[rid]
            px = array_from_wire(rec["px"])
            co._pixels[int(rid)] = px
            ds = rec.get("deadline_steps")
            co._dispatch(int(rid), px,
                         deadline_steps=None if ds is None else int(ds))
        return co

    # ---- lifecycle -------------------------------------------------------
    def close(self) -> None:
        for h in self.workers:
            if h.alive:
                try:
                    write_msg(h.wfd, {"op": "shutdown"}, 10.0)
                    read_msg(h.rfd, 10.0)
                except Exception:
                    pass
                self._kill_worker(h)
        try:
            self._ledger.close()
        except Exception:
            pass

    def __enter__(self) -> "ClusterCoordinator":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


# ---- worker process main --------------------------------------------------

def _worker_main(argv: list[str]) -> int:
    """One engine process: blocking RPC loop over inherited pipe fds.

    Liveness is the coordinator's problem (every read here blocks
    forever); injected process faults execute *here* — ``worker_kill``
    dies mid-protocol with no reply, ``worker_hang`` sleeps through the
    heartbeat deadline — so the coordinator's detection path is the real
    one, not a simulation.
    """
    rfd, wfd = int(argv[0]), int(argv[1])
    engine = None
    plan = None
    worker_id = 0
    wledger: Ledger | None = None
    shipped: set[int] = set()

    def ship_new_results() -> list[dict]:
        """Wire + ledger-replicate results not yet shipped upstream (the
        ledger line lands BEFORE the reply frame — a result computed but
        never acknowledged still survives a coordinator crash)."""
        out = []
        for rid in sorted(set(engine.results) - shipped):
            w = result_to_wire(engine.results[rid])
            if wledger is not None:
                wledger.append({"kind": "result", "rid": int(rid), **w})
            shipped.add(rid)
            out.append(w)
        return out

    while True:
        try:
            msg = read_msg(rfd)
        except (EOFError, OSError):
            return 0
        op = msg.get("op")
        try:
            if op == "init":
                from .faults import FaultInjector
                from .snn_engine import SNNStreamEngine
                from .wire import (fault_cfg_from_wire, params_from_wire,
                                   plan_from_wire, snn_cfg_from_wire)
                cfg = snn_cfg_from_wire(msg["snn_cfg"])
                params_q = params_from_wire(msg["params"])
                worker_id = int(msg["worker_id"])
                plan = plan_from_wire(msg.get("plan"))
                injector = (FaultInjector(plan, worker_id)
                            if plan is not None
                            and plan.engine_relevant(worker_id) else None)
                engine = SNNStreamEngine(
                    params_q, cfg, batch_size=int(msg["lanes"]),
                    chunk_steps=int(msg["chunk_steps"]),
                    patience=int(msg["patience"]), seed=int(msg["seed"]),
                    backend=msg.get("backend"), engine_id=worker_id,
                    injector=injector,
                    fault_cfg=fault_cfg_from_wire(msg.get("fault_cfg")),
                    initial_weight_version=int(
                        msg.get("initial_weight_version", 0)))
                if msg.get("ledger_path"):
                    wledger = Ledger(msg["ledger_path"])
                write_msg(wfd, {"ok": True, "backend": engine.backend})
            elif op == "submit":
                from ..core.telemetry import engine_load_to_wire
                from .wire import array_from_wire as afw
                engine.submit(afw(msg["px"]), request_id=int(msg["rid"]))
                write_msg(wfd, {
                    "ok": True, "pending": engine.pending,
                    "load": engine_load_to_wire(engine.load_summary())})
            elif op == "adopt":
                from ..core.telemetry import engine_load_to_wire
                from .wire import lane_from_wire
                engine.adopt(int(msg["rid"]), lane_from_wire(msg["row"]))
                write_msg(wfd, {
                    "ok": True, "pending": engine.pending,
                    "load": engine_load_to_wire(engine.load_summary())})
            elif op == "ensure_version":
                from .wire import planes_from_wire
                v = int(msg["version"])
                engine.bank.ensure(
                    v, engine._place_weights(planes_from_wire(msg["planes"])))
                write_msg(wfd, {"ok": True,
                                "versions": sorted(engine.bank.versions)})
            elif op == "begin_rollout":
                from .wire import params_from_wire
                v = engine.begin_rollout(params_from_wire(msg["params"]))
                write_msg(wfd, {"ok": True, "version": int(v),
                                "versions": sorted(engine.bank.versions)})
            elif op == "probe":
                # one chunk dispatch on the (possibly empty) tile — the
                # promotion probe, and the compile warm-up that keeps
                # later step RPCs inside the heartbeat deadline
                engine._dispatch_chunk(engine.lanes)
                write_msg(wfd, {"ok": True,
                                "backend": engine.backend_effective})
            elif op == "step":
                rnd = int(msg["round"])
                if plan is not None:
                    if plan.worker_kill(worker_id, rnd) is not None:
                        os._exit(13)   # injected crash: no reply, no cleanup
                    if plan.worker_hang(worker_id, rnd):
                        time.sleep(3600.0)   # heartbeat deadline kills us
                engine.step()
                # second compaction: harvest lanes the chunk just retired
                # so their results ship THIS reply, and the checkpoint
                # below covers only still-active lanes
                engine._admit_and_compact()
                from .wire import lane_to_wire
                from ..core.telemetry import engine_load_to_wire
                write_msg(wfd, {
                    "ok": True, "done": ship_new_results(),
                    "checkpoint": [[int(rid), lane_to_wire(row)]
                                   for rid, row in engine.checkpoint_lanes()],
                    "load": engine_load_to_wire(engine.load_summary()),
                    "pending": engine.pending,
                    "versions": sorted(engine.bank.versions)})
            elif op == "ping":
                from ..core.telemetry import engine_load_to_wire
                write_msg(wfd, {
                    "ok": True,
                    "load": engine_load_to_wire(engine.load_summary()),
                    "pending": engine.pending,
                    "versions": sorted(engine.bank.versions)})
            elif op == "drain":
                engine.run(max_chunks=0)   # final harvest
                write_msg(wfd, {"ok": True, "done": ship_new_results(),
                                "pending": engine.pending})
            elif op == "shutdown":
                write_msg(wfd, {"ok": True})
                if wledger is not None:
                    wledger.close()
                return 0
            else:
                write_msg(wfd, {"ok": False,
                                "error": f"unknown op {op!r}"})
        except Exception as e:  # noqa: BLE001 — every fault goes upstream
            try:
                write_msg(wfd, {"ok": False,
                                "error": f"{type(e).__name__}: {e}"})
            except OSError:
                return 1
    return 0


if __name__ == "__main__":
    sys.exit(_worker_main(sys.argv[1:]))
