"""Adaptive dispatch control from live telemetry (the serving control
plane the activity side channel exists for).

Hardware/software co-designs (SparrowSNN; the Bouvier et al. 2020 survey's
activity-monitoring control plane) feed *measured* spike statistics back
into scheduling instead of compile-time guesses.  This module is that
loop's host side: :class:`TelemetryController` consumes per-chunk
:class:`ChunkSummary` observations (reduced from the structured
``core.telemetry.ChunkTelemetry`` record every backend emits) and retunes
two performance-facing knobs between chunk dispatches:

  * the **masked-vs-MXU dispatch threshold** — the runtime density
    dispatch of ``kernels.ops.spike_matmul_op(mode="auto")`` branches on
    this boundary; the controller walks it with an EWMA of the observed
    input density so marginal batches route to the datapath that wins on
    the traffic actually being served, not on the 0.25 guess;
  * the **chunk length** of the next streaming dispatch — lanes that
    retire mid-chunk burn host-invisible steps until the chunk ends, so
    a high observed retirement rate shrinks the chunk (tighter harvest
    granularity) while retirement-free steady state grows it (fewer
    host syncs per window step).

Both knobs are *value-neutral by construction*: the masked and MXU
datapaths compute the identical integer contraction, and chunked window
execution is bit-identical under any split (the property tests pin both).
Adaptivity can therefore never change predictions, retirement steps or
energy counters — only wall-clock.  **Frozen mode** (the default, and
what CI pins) bypasses every observation: the controller returns exactly
the static threshold (``SNNConfig.spike_density_threshold`` → env →
``kernels.ops.SPIKE_DENSITY_THRESHOLD``) and the configured chunk length,
with zero device syncs — today's behavior, reproduced bit-for-bit.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

from ..core.telemetry import ChunkTelemetry, resolve_density_threshold

__all__ = ["AdaptiveDispatchConfig", "ChunkSummary", "TelemetryController",
           "adaptive_config_from_env", "make_controller", "summarize_chunk"]


@dataclass(frozen=True)
class AdaptiveDispatchConfig:
    """Knobs of the serving telemetry controller.

    ``adaptive=False`` is frozen mode: static threshold, static chunk
    length, no telemetry readbacks.  The env override
    ``REPRO_ADAPTIVE_DISPATCH=1`` (see :func:`adaptive_config_from_env`)
    flips the default on for a whole run — CI uses it to prove adaptivity
    is value-neutral across the entire suite.
    """

    adaptive: bool = False
    # EWMA weight of the newest chunk's observed density (0 < alpha <= 1).
    ewma_alpha: float = 0.25
    # Dispatch boundary = clip(gain · density_ewma, lo, hi): traffic much
    # sparser than the static guess pulls the masked/MXU boundary down to
    # just above typical density (marginal batches go MXU only when truly
    # denser than the traffic), denser traffic pushes it up to the cap.
    threshold_gain: float = 1.5
    threshold_min: float = 0.05
    threshold_max: float = 0.5
    # Chunk-length control: shrink when ≥ shrink_retire_frac of the active
    # lanes retired inside the chunk, grow after grow_patience consecutive
    # retirement-free chunks.
    min_chunk_steps: int = 2
    max_chunk_steps: int = 16
    shrink_retire_frac: float = 0.25
    grow_patience: int = 2


def adaptive_config_from_env() -> AdaptiveDispatchConfig:
    """Default controller config: frozen unless REPRO_ADAPTIVE_DISPATCH=1."""
    on = os.environ.get("REPRO_ADAPTIVE_DISPATCH", "0") == "1"
    return AdaptiveDispatchConfig(adaptive=on)


@dataclass(frozen=True)
class ChunkSummary:
    """Host-side reduction of one chunk's telemetry (plain floats/ints)."""

    density_in: float        # mean input-layer spike density, active steps
    layer_densities: tuple   # per-layer mean input densities
    executed_adds: int       # Σ telemetry adds this chunk (energy channel)
    tiles_skipped: int       # Σ skipped MXU tile pairs this chunk
    lanes_retired: int       # lanes the stability gate froze this chunk
    lanes_active: int        # lanes active when the chunk was dispatched
    active_lane_steps: int   # Σ per-lane steps actually consumed


def summarize_chunk(tel: ChunkTelemetry, layer_sizes, *,
                    steps_before, steps_after,
                    active_before, active_after) -> ChunkSummary:
    """Reduce a chunk's telemetry record to controller observations.

    Densities are occupancy-weighted: frozen lanes contribute zero rows to
    ``n_spk`` AND zero consumed steps, so dividing by the consumed
    lane-steps × fan-in measures the density of the work the device
    actually executed.  Forces a device→host transfer — callers in frozen
    mode skip this entirely (the no-sync guarantee).
    """
    n_spk = np.asarray(tel.n_spk)                    # (chunk, L, B)
    steps_b = np.asarray(steps_before)
    steps_a = np.asarray(steps_after)
    act_b = np.asarray(active_before)
    act_a = np.asarray(active_after)
    lane_steps = int((steps_a - steps_b).sum())
    fan_in = np.asarray(layer_sizes[:-1], np.float64)
    spk_per_layer = n_spk.sum(axis=(0, 2)).astype(np.float64)  # (L,)
    denom = max(1, lane_steps)
    layer_densities = tuple(spk_per_layer / (denom * fan_in))
    tel_adds = n_spk * np.asarray(tel.n_en)
    return ChunkSummary(
        density_in=float(layer_densities[0]),
        layer_densities=layer_densities,
        executed_adds=int(tel_adds.sum()),
        tiles_skipped=int(np.asarray(tel.tiles_skipped).sum()),
        lanes_retired=int(np.logical_and(act_b, ~act_a).sum()),
        lanes_active=int(act_b.sum()),
        active_lane_steps=lane_steps,
    )


@dataclass
class TelemetryController:
    """EWMA density estimator + the two dispatch decisions it drives.

    Deterministic: the decision trajectory is a pure function of the
    observation sequence, so the same traffic replayed gives the same
    thresholds and chunk lengths (the benchmark records the trajectory as
    a contract artifact).  In frozen mode every property returns the
    static choice and :meth:`observe` is a no-op — bit-for-bit today's
    behavior.
    """

    cfg: AdaptiveDispatchConfig
    static_threshold: float
    static_chunk_steps: int
    num_steps: int
    density_ewma: float | None = None
    history: list = field(default_factory=list)
    _chunk: int = 0
    _quiet: int = 0

    def __post_init__(self):
        self._chunk = self.static_chunk_steps

    @property
    def frozen(self) -> bool:
        return not self.cfg.adaptive

    @property
    def dispatch_threshold(self) -> float:
        """Masked-vs-MXU density boundary for the next dispatch."""
        if self.frozen or self.density_ewma is None:
            return self.static_threshold
        lo, hi = self.cfg.threshold_min, self.cfg.threshold_max
        return float(np.clip(self.cfg.threshold_gain * self.density_ewma,
                             lo, hi))

    @property
    def chunk_steps(self) -> int:
        """Window steps the next streaming chunk should execute."""
        if self.frozen:
            return self.static_chunk_steps
        return max(1, min(self._chunk, self.num_steps))

    @property
    def min_chunk_steps(self) -> int:
        """Smallest chunk the controller may pick (drive-loop bounds)."""
        if self.frozen:
            return self.static_chunk_steps
        return max(1, min(self.cfg.min_chunk_steps, self.num_steps))

    @classmethod
    def from_cache(cls, tuned, *,
                   cfg_adaptive: AdaptiveDispatchConfig | None = None,
                   num_steps: int) -> "TelemetryController":
        """Start at cache-tuned values instead of the static defaults.

        ``tuned`` is a :class:`repro.tune.cache.TunedShapes` (anything
        with ``chunk_steps`` / ``spike_density_threshold`` attributes):
        the measured winner becomes the controller's *static* choice, so
        frozen mode — still the default, still what CI pins — serves the
        tuned shapes with zero readbacks, and adaptive mode walks its
        shrink/grow law from the tuned starting point rather than from
        the heuristics.  Duck-typed on purpose: ``serve`` must not
        import ``repro.tune`` at module scope (tune's search side
        imports serve).
        """
        return cls(
            cfg=(adaptive_config_from_env() if cfg_adaptive is None
                 else cfg_adaptive),
            static_threshold=float(tuned.spike_density_threshold),
            static_chunk_steps=int(tuned.chunk_steps),
            num_steps=num_steps)

    def observe(self, summary: ChunkSummary) -> None:
        """Fold one chunk's summary into the estimator and retune.

        No-op in frozen mode.  Chunks that consumed no lane-steps carry
        no density signal and leave the estimator untouched.
        """
        if self.frozen:
            return
        c = self.cfg
        if summary.active_lane_steps > 0:
            d = summary.density_in
            self.density_ewma = (d if self.density_ewma is None else
                                 (1 - c.ewma_alpha) * self.density_ewma
                                 + c.ewma_alpha * d)
        # chunk-length control from the observed retirement rate
        if summary.lanes_active > 0:
            frac = summary.lanes_retired / summary.lanes_active
            if frac >= c.shrink_retire_frac:
                # proportional shrink: one step at the trigger fraction,
                # one more per additional trigger-width of overshoot — a
                # chunk that retired every lane converges in one
                # observation instead of limping down a step at a time.
                # The clamp bounds are unchanged, and so is the behavior
                # exactly AT the trigger (step 1), which is what keeps
                # the PR 8 speculation-discard guard semantics intact:
                # any retune still lands between chunk dispatches and
                # trips `_spec_steps != controller.chunk_steps`.
                step = 1 + int((frac - c.shrink_retire_frac)
                               / c.shrink_retire_frac)
                self._chunk = max(c.min_chunk_steps, self._chunk - step)
                self._quiet = 0
            elif summary.lanes_retired == 0:
                self._quiet += 1
                if self._quiet >= c.grow_patience:
                    self._chunk = min(c.max_chunk_steps, self._chunk + 1)
                    self._quiet = 0
            else:
                self._quiet = 0
        self.history.append({
            "density_in": summary.density_in,
            "density_ewma": self.density_ewma,
            "dispatch_threshold": self.dispatch_threshold,
            "chunk_steps": self.chunk_steps,
            "lanes_retired": summary.lanes_retired,
            "executed_adds": summary.executed_adds,
            "tiles_skipped": summary.tiles_skipped,
        })


def make_controller(cfg_adaptive: AdaptiveDispatchConfig | None,
                    *, spike_density_threshold: float | None,
                    chunk_steps: int, num_steps: int) -> TelemetryController:
    """Engine-side constructor: None → the env-resolved default config,
    static threshold resolved through config → env → the historical
    ``kernels.ops.SPIKE_DENSITY_THRESHOLD`` constant."""
    return TelemetryController(
        cfg=(adaptive_config_from_env() if cfg_adaptive is None
             else cfg_adaptive),
        static_threshold=resolve_density_threshold(spike_density_threshold),
        static_chunk_steps=chunk_steps,
        num_steps=num_steps)
