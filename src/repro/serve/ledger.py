"""Write-ahead replicated accounting ledger for the cluster coordinator.

PR 7's never-silent contract — ``results ∪ shed ∪ faulted`` exactly
partitions the submitted request ids — lived in one Python process; the
coordinator dying took the whole ledger (and the partition proof) with
it.  This module makes the accounting crash-proof:

* every host appends one JSON line per accounting event to its own
  **append-only JSONL file** (the coordinator logs ``submit``/``shed``/
  ``fault``/``result`` events, every worker *replicates* its own
  ``result`` lines locally before shipping them over RPC — so a result
  computed but never acknowledged still survives a coordinator crash);
* writes are **write-ahead**: the ``submit`` line (with the request's
  pixels) lands on disk before the request is routed, so a restarted
  coordinator can re-run any window that was in flight — a window is a
  pure function of ``(seed, request_id, pixels)``, so the re-run is
  bit-identical to the never-crashed run;
* :func:`read_ledger` tolerates a **torn final line** (the crash arrived
  mid-``write``): the trailing partial record is dropped, while a
  corrupt line anywhere *else* is a real integrity failure and raises;
  reopening a ledger for appending (:class:`Ledger`) truncates such a
  torn tail first, so a recovered process never welds its first record
  onto the previous incarnation's partial line;
* :func:`recover_accounting` folds any set of ledger files back into
  the three maps plus the ordered outstanding-submission list, with
  **exactly-once** semantics: the first terminal record per request id
  wins, and a ``result`` always beats a ``fault``/``shed`` for the same
  id (a worker may have replicated a result the coordinator never saw
  before declaring the request lost — the computed answer is the truth).
"""

from __future__ import annotations

import json
import os

__all__ = ["Ledger", "read_ledger", "recover_accounting",
           "LedgerCorruptError"]


class LedgerCorruptError(ValueError):
    """A ledger line that is not a torn tail failed to parse."""


class Ledger:
    """Append-only JSONL writer with per-record durability.

    Each :meth:`append` writes one compact JSON line, flushes, and
    fsyncs — a record either fully precedes a crash or is the single
    torn tail the reader drops.  Append mode keeps restarts cheap: a
    recovered coordinator reopens the same file and keeps appending —
    but **reopen repairs first**: if the previous incarnation crashed
    mid-append, the file ends in a partial line, and appending straight
    onto it would merge two records into one corrupt line (turning the
    recoverable torn tail into a mid-file integrity failure).  So
    :meth:`__init__` truncates an unterminated final line before the
    first append — exactly the record :func:`read_ledger` would have
    dropped anyway.
    """

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._repair_torn_tail(path)
        self._f = open(path, "a", encoding="utf-8")

    @staticmethod
    def _repair_torn_tail(path: str) -> None:
        """Truncate a partial (newline-less) final line left by a crash.

        Every append is ``<json>\\n`` with no interior newlines, so a
        file not ending in ``\\n`` ends in a torn record; cutting back to
        the last newline restores the append-only invariant for the new
        incarnation without touching any complete record.
        """
        try:
            f = open(path, "r+b")
        except FileNotFoundError:
            return
        with f:
            data = f.read()
            if not data or data.endswith(b"\n"):
                return
            f.truncate(data.rfind(b"\n") + 1)
            f.flush()
            os.fsync(f.fileno())

    def append(self, record: dict) -> None:
        self._f.write(json.dumps(record, separators=(",", ":")) + "\n")
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


def read_ledger(path: str) -> list[dict]:
    """Parse one JSONL ledger file, dropping a torn final line.

    A crash mid-append leaves at most one partial record, and only at
    the tail (appends are sequential and fsynced); a malformed line
    *followed by valid lines* cannot come from a torn write and raises
    :class:`LedgerCorruptError` instead of being skipped silently.
    """
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")
    records: list[dict] = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as e:
            if all(not later.strip() for later in lines[i + 1:]):
                break  # torn tail: the crash interrupted this append
            raise LedgerCorruptError(
                f"{path}:{i + 1}: corrupt ledger line is not the torn "
                f"tail ({e}) — the file was modified outside the "
                f"append-only protocol") from e
    return records


def recover_accounting(paths: list[str]) -> dict:
    """Reconstruct the accounting state from a set of ledger files.

    Returns ``{"submitted": [(rid, record), ...] in submit order,
    "results": {rid: record}, "shed": {rid: record},
    "faulted": {rid: record}, "outstanding": [rid, ...],
    "rollouts": [record, ...] in append order}`` — ``rollout`` records
    carry the wire-encoded params of every completed weight rollout, so
    a recovered coordinator can replay the fleet up to its pre-crash
    weight version before re-running the outstanding ids.

    Exactly-once: per request id the first terminal record wins within
    its class, and ``result`` records (from any replica) take precedence
    over ``shed``/``fault`` — a coordinator that faulted a request whose
    worker had already durably computed (and replicated) the answer must
    land it in ``results``, never in both maps.  Ids submitted with no
    terminal record anywhere are ``outstanding`` — the restarted
    coordinator re-runs them from their write-ahead pixels.
    """
    submits: dict[int, dict] = {}
    order: list[int] = []
    results: dict[int, dict] = {}
    shed: dict[int, dict] = {}
    faulted: dict[int, dict] = {}
    rollouts: list[dict] = []
    for path in paths:
        for rec in read_ledger(path):
            kind = rec.get("kind")
            rid = rec.get("rid")
            if kind == "submit" and rid not in submits:
                submits[rid] = rec
                order.append(rid)
            elif kind == "result" and rid not in results:
                results[rid] = rec
            elif kind == "shed" and rid not in shed:
                shed[rid] = rec
            elif kind == "fault" and rid not in faulted:
                faulted[rid] = rec
            elif kind == "rollout":
                rollouts.append(rec)
    # results win over the other terminal classes (see docstring)
    for rid in results:
        shed.pop(rid, None)
        faulted.pop(rid, None)
    # between shed and fault, first writer wins is unknowable across
    # files — prefer shed (an admission decision made before any fault)
    for rid in shed:
        faulted.pop(rid, None)
    terminal = set(results) | set(shed) | set(faulted)
    outstanding = [rid for rid in order if rid not in terminal]
    return {"submitted": [(rid, submits[rid]) for rid in order],
            "results": results, "shed": shed, "faulted": faulted,
            "outstanding": outstanding, "rollouts": rollouts}
