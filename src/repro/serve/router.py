"""Multi-host serving tier: telemetry-routed spraying + SLO admission.

The per-device datapath (fused megakernel, lane mesh, adaptive telemetry)
serves one engine's worth of traffic; this module is the tier above it —
the front end a fleet deployment actually exposes.  An
:class:`SNNServingTier` owns N per-host engines (plain or sharded — in
one process here, but nothing below the ``submit``/``step`` surface knows
that) and makes the three decisions a fleet front end must make:

**Routing** — requests spray **least-loaded** across engines, scored by
the load signals the serving telemetry loop already maintains for free
(:meth:`SNNStreamEngine.load_summary` → ``core.telemetry.EngineLoad``):
lane occupancy, host-queue depth, the measured mean service window
(early-exit traffic drains faster — the retirement-rate signal), and the
controller's density EWMA when adaptive.  Scoring is a pure function
(``core.telemetry.load_score``) with a deterministic lowest-index
tie-break, so a replayed submission stream routes identically — CI
reproducibility is a feature of the router, not an accident.

**SLO-aware admission** — the paper's active-pruning/early-exit design
makes per-request latency *structurally* variable, which is exactly the
regime where deadline-aware shedding beats FIFO queueing (SparrowSNN
makes the same argument for deadline-bound edge inference).  Each request
carries a deadline in **window steps** (the currency of
``RequestResult.steps``) and a **priority class**; a request whose
completion estimate (``core.telemetry.estimate_eta_steps``, fed by the
measured retirement rate) exceeds its deadline is **shed at admission** —
recorded in :attr:`SNNServingTier.shed` with the estimate that rejected
it, never silently dropped.  Under overload (every engine's host queue at
``queue_limit``) the tier sheds **lowest-priority-first**: a higher-class
arrival displaces the newest lowest-class queued request instead of
queueing forever behind it.

**Zero-drain weight rollout** — :meth:`begin_rollout` broadcasts
version-tagged packed planes to every engine (``serve.rollout``):
in-flight windows finish on their admission-time weights, new admissions
bind the new version, and the rollout completes when the last old-version
lane retires fleet-wide.  No admission pause, no drained windows.

The whole tier rides the existing bit-identity contract: routing and
shedding change *which* engine serves a request (or whether it is served)
— never its prediction.  Every engine is constructed with the tier's
seed, and requests carry their tier-global id into
``engine.submit(request_id=...)``, so a request's window is a pure
function of ``(seed, id, pixels)`` regardless of placement — the
property test replays random schedules against single-engine serving.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.snn import SNNConfig
from ..core.telemetry import estimate_eta_steps, load_score
from .snn_engine import RequestResult, SNNStreamEngine

__all__ = ["DEFAULT_PRIORITY_CLASSES", "ShedRecord", "SNNServingTier"]

# Priority classes, ordered lowest → highest.  Overload shedding walks
# this order from the left; deployments override the tuple wholesale
# (configs.snn_mnist.SNNServingTierConfig threads it through).
DEFAULT_PRIORITY_CLASSES = ("batch", "standard", "interactive")


@dataclass(frozen=True)
class ShedRecord:
    """Why a request was not served (the recorded, auditable drop).

    ``reason`` is ``"deadline"`` (the admission-time completion estimate
    exceeded the request's deadline) or ``"overload"`` (every engine
    queue was full and the request was — or was displaced by — a
    higher-priority arrival).
    """

    request_id: int
    reason: str                    # "deadline" | "overload"
    priority: str
    priority_level: int
    deadline_steps: int | None
    eta_steps: float | None = None  # the estimate that rejected it
    displaced_by: int | None = None  # overload: the admitted higher-prio rid


class SNNServingTier:
    """Front-end router over N same-seed streaming engines (class doc
    above; construction knobs mirror ``SNNServingTierConfig``).

    ``sharded=True`` partitions the visible jax devices into
    ``num_engines`` contiguous slices — each engine becomes a
    ``ShardedSNNStreamEngine`` over its own slice's mesh, i.e. a
    simulated per-host lane mesh (CI runs two 4-device "hosts" on an
    8-device forced-host CPU).  ``shedding=False`` disables both shed
    paths (every request is eventually served — the bit-identity
    property's configuration).
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *,
                 num_engines: int = 2, lanes_per_engine: int = 8,
                 chunk_steps: int = 4, patience: int = 2, seed: int = 0,
                 backend: str | None = None,
                 priority_classes: tuple = DEFAULT_PRIORITY_CLASSES,
                 default_priority: str = "standard",
                 default_deadline_steps: int | None = None,
                 queue_limit: int | None = None, shedding: bool = True,
                 sharded: bool = False,
                 devices_per_engine: int | None = None,
                 adaptive=None):
        if num_engines < 1:
            raise ValueError(f"num_engines must be >= 1, got {num_engines}")
        if default_priority not in priority_classes:
            raise ValueError(f"default priority {default_priority!r} not in "
                             f"{priority_classes}")
        self.priority_classes = tuple(priority_classes)
        self.default_priority = default_priority
        self.default_deadline_steps = default_deadline_steps
        self.queue_limit = queue_limit
        self.shedding = shedding
        self.seed = seed
        self.engines: list[SNNStreamEngine] = []
        if sharded:
            import jax

            from ..distributed.sharding import make_device_mesh
            from .snn_engine import ShardedSNNStreamEngine
            devs = jax.devices()
            per = (devices_per_engine if devices_per_engine is not None
                   else len(devs) // num_engines)
            if per < 1 or per * num_engines > len(devs):
                raise ValueError(
                    f"cannot carve {num_engines} × {per}-device hosts out "
                    f"of {len(devs)} visible devices")
            for i in range(num_engines):
                mesh = make_device_mesh(
                    (per,), ("data",), devices=devs[i * per:(i + 1) * per])
                self.engines.append(ShardedSNNStreamEngine(
                    params_q, cfg, mesh=mesh,
                    batch_size=lanes_per_engine, chunk_steps=chunk_steps,
                    patience=patience, seed=seed, backend=backend,
                    adaptive=adaptive))
        else:
            for i in range(num_engines):
                self.engines.append(SNNStreamEngine(
                    params_q, cfg, batch_size=lanes_per_engine,
                    chunk_steps=chunk_steps, patience=patience, seed=seed,
                    backend=backend, adaptive=adaptive))
        self.shed: dict[int, ShedRecord] = {}
        self._assignment: dict[int, int] = {}    # rid -> engine index
        self._meta: dict[int, tuple] = {}        # rid -> (level, prio, ddl)
        self._next_id = 0
        self.stats = {"routed_per_engine": [0] * num_engines,
                      "shed_deadline": 0, "shed_overload": 0,
                      "displaced": 0}

    # ---- routing --------------------------------------------------------
    def _route_index(self) -> int:
        """Least-loaded engine; ties break on the lowest index (the
        deterministic spray order the reproducibility tests replay)."""
        scores = [(load_score(e.load_summary()), i)
                  for i, e in enumerate(self.engines)]
        return min(scores)[1]

    def _level(self, priority: str) -> int:
        try:
            return self.priority_classes.index(priority)
        except ValueError:
            raise ValueError(f"unknown priority class {priority!r}: tier "
                             f"serves {self.priority_classes}") from None

    def _shed(self, rid: int, reason: str, priority: str, level: int,
              deadline: int | None, *, eta: float | None = None,
              displaced_by: int | None = None) -> None:
        self.shed[rid] = ShedRecord(
            request_id=rid, reason=reason, priority=priority,
            priority_level=level, deadline_steps=deadline, eta_steps=eta,
            displaced_by=displaced_by)
        self.stats[f"shed_{reason}"] += 1

    def _overload_victim(self) -> int | None:
        """The queued request overload shedding would displace: lowest
        priority class first, newest arrival within the class (its wait
        so far is the smallest sunk cost).  None if any queue has room."""
        if self.queue_limit is None:
            return None
        if any(len(e.queue) < self.queue_limit for e in self.engines):
            return None
        queued = [rid for e in self.engines for rid, _ in e.queue]
        if not queued:
            return None
        return max(queued, key=lambda r: (-self._meta[r][0], r))

    def _evict(self, victim: int) -> int:
        """Remove a queued request from its engine; returns the engine."""
        idx = self._assignment.pop(victim)
        eng = self.engines[idx]
        eng.queue = [q for q in eng.queue if q[0] != victim]
        self.stats["routed_per_engine"][idx] -= 1
        return idx

    # ---- intake ---------------------------------------------------------
    def submit(self, pixels_u8, *, priority: str | None = None,
               deadline_steps: int | None = None) -> int:
        """Admit (or shed) one request; returns its tier-global id.

        Admission runs entirely at submit time — shed decisions are never
        deferred to a queue scan, so a caller learns a request's fate
        (``rid in tier.shed``) as soon as the tier does.
        """
        rid = self._next_id
        self._next_id += 1
        priority = self.default_priority if priority is None else priority
        level = self._level(priority)
        deadline = (self.default_deadline_steps if deadline_steps is None
                    else deadline_steps)
        self._meta[rid] = (level, priority, deadline)
        if not self.shedding:
            self._admit(rid, pixels_u8, self._route_index())
            return rid
        # overload first: a doomed-by-deadline request must not displace a
        # queued one
        victim = self._overload_victim()
        if victim is not None:
            if level <= self._meta[victim][0]:
                # nothing queued is lower-priority than the arrival
                self._shed(rid, "overload", priority, level, deadline)
                return rid
        idx = (self._route_index() if victim is None else None)
        eta = estimate_eta_steps(
            self.engines[idx if idx is not None
                         else self._assignment[victim]].load_summary())
        if deadline is not None and eta > deadline:
            self._shed(rid, "deadline", priority, level, deadline, eta=eta)
            return rid
        if victim is not None:
            vl, vp, vd = self._meta[victim]
            self._shed(victim, "overload", vp, vl, vd, displaced_by=rid)
            idx = self._evict(victim)
            self.stats["displaced"] += 1
        self._admit(rid, pixels_u8, idx)
        return rid

    def _admit(self, rid: int, pixels_u8, idx: int) -> None:
        self.engines[idx].submit(pixels_u8, request_id=rid)
        self._assignment[rid] = idx
        self.stats["routed_per_engine"][idx] += 1

    # ---- drive ----------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(e.pending for e in self.engines)

    def step(self) -> list[int]:
        """One chunk on every engine with work; returns finished rids."""
        done = []
        for e in self.engines:
            if e.pending:
                done.extend(e.step())
        return done

    def run(self, max_chunks: int | None = None) -> dict[int, RequestResult]:
        """Drive all engines until every admitted request has a result.

        Engines advance in lockstep rounds (one chunk each per round) —
        the in-process stand-in for N hosts running concurrently.  Shed
        requests are *not* in the returned dict; they are in
        :attr:`shed`, which partitions every submitted id with
        :attr:`results`.
        """
        limit = max_chunks if max_chunks is not None else sum(
            (e.pending + e.batch_size)
            * (e.cfg.num_steps // max(1, e.controller.min_chunk_steps) + 2)
            for e in self.engines)
        for _ in range(limit):
            if self.pending == 0:
                break
            self.step()
        for e in self.engines:
            e.run(max_chunks=0)     # final harvest of retired lanes
        return self.results

    @property
    def results(self) -> dict[int, RequestResult]:
        out: dict[int, RequestResult] = {}
        for e in self.engines:
            out.update(e.results)
        return out

    def load_report(self) -> list:
        """Per-engine ``EngineLoad`` snapshot (ordered by engine index)."""
        return [e.load_summary() for e in self.engines]

    # ---- weight rollout -------------------------------------------------
    def begin_rollout(self, params_q: dict) -> int:
        """Broadcast new packed weight planes to every engine, zero-drain.

        Returns the fleet-wide new version (engines move in lockstep —
        they were constructed together and roll together).  Completion is
        per-engine as its last old-version lane retires;
        :attr:`rollout_active` goes False when the whole fleet finished.
        """
        versions = {e.begin_rollout(params_q) for e in self.engines}
        assert len(versions) == 1, f"engines out of lockstep: {versions}"
        return versions.pop()

    @property
    def rollout_active(self) -> bool:
        return any(e.bank.rolling for e in self.engines)

    def rollout_history(self) -> list:
        """Per-engine rollout event logs (ordered by engine index)."""
        return [list(e.bank.history) for e in self.engines]
