"""Multi-host serving tier: telemetry-routed spraying + SLO admission.

The per-device datapath (fused megakernel, lane mesh, adaptive telemetry)
serves one engine's worth of traffic; this module is the tier above it —
the front end a fleet deployment actually exposes.  An
:class:`SNNServingTier` owns N per-host engines (plain or sharded — in
one process here, but nothing below the ``submit``/``step`` surface knows
that) and makes the three decisions a fleet front end must make:

**Routing** — requests spray **least-loaded** across engines, scored by
the load signals the serving telemetry loop already maintains for free
(:meth:`SNNStreamEngine.load_summary` → ``core.telemetry.EngineLoad``):
lane occupancy, host-queue depth, the measured mean service window
(early-exit traffic drains faster — the retirement-rate signal), and the
controller's density EWMA when adaptive.  Scoring is a pure function
(``core.telemetry.load_score``) with a deterministic lowest-index
tie-break, so a replayed submission stream routes identically — CI
reproducibility is a feature of the router, not an accident.

**SLO-aware admission** — the paper's active-pruning/early-exit design
makes per-request latency *structurally* variable, which is exactly the
regime where deadline-aware shedding beats FIFO queueing (SparrowSNN
makes the same argument for deadline-bound edge inference).  Each request
carries a deadline in **window steps** (the currency of
``RequestResult.steps``) and a **priority class**; a request whose
completion estimate (``core.telemetry.estimate_eta_steps``, fed by the
measured retirement rate) exceeds its deadline is **shed at admission** —
recorded in :attr:`SNNServingTier.shed` with the estimate that rejected
it, never silently dropped.  Under overload (every engine's host queue at
``queue_limit``) the tier sheds **lowest-priority-first**: a higher-class
arrival displaces the newest lowest-class queued request instead of
queueing forever behind it.

**Zero-drain weight rollout** — :meth:`begin_rollout` broadcasts
version-tagged packed planes to every engine (``serve.rollout``):
in-flight windows finish on their admission-time weights, new admissions
bind the new version, and the rollout completes when the last old-version
lane retires fleet-wide.  No admission pause, no drained windows.

**Failover** — engines fail (``serve.faults``: injected deterministically,
or for real once the runtime meets real hardware).  The tier catches the
typed escalations its engines raise mid-step: a *poison request* is
evicted from its lane and retried on another engine (quarantined with its
replay seed after ``quarantine_after`` faults across engines); a *failed
engine* (dispatch faults past the retry/demotion budget, the
chunk-deadline watchdog, device loss) is marked dead, its host queue
re-routed, and its surviving lanes **evacuated**: each in-flight
``LaneState`` row is snapshotted at the last committed chunk boundary and
re-admitted mid-window onto a healthy engine, where it resumes
bit-identically (the chunked==one-shot property — the row IS the
checkpoint).  Old weight versions an adopting engine already dropped are
restored from the tier's host copies (``WeightBank.ensure``), so a
rollout can never complete while an evacuated old-version lane is still
draining.  Windows that cannot be recovered (state lost with the device,
no healthy engine left) are recorded in :attr:`faulted` as
:class:`~.faults.FaultRecord`\\ s — never silently dropped:
``results ∪ shed ∪ faulted`` exactly partitions the submitted ids.

The whole tier rides the existing bit-identity contract: routing,
shedding and failover change *which* engine serves a request (or whether
it is served) — never its prediction.  Every engine is constructed with
the tier's seed, and requests carry their tier-global id into
``engine.submit(request_id=...)``, so a request's window is a pure
function of ``(seed, id, pixels)`` regardless of placement — the
property test replays random schedules against single-engine serving.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass

from ..core.snn import SNNConfig
from ..core.telemetry import estimate_eta_steps, load_score
from .faults import (EngineFailure, FaultInjector, FaultPlan, FaultRecord,
                     FaultToleranceConfig, PoisonDispatchError)
from .snn_engine import RequestResult, SNNStreamEngine

__all__ = ["DEFAULT_PRIORITY_CLASSES", "ShedRecord", "SNNServingTier"]

# Priority classes, ordered lowest → highest.  Overload shedding walks
# this order from the left; deployments override the tuple wholesale
# (configs.snn_mnist.SNNServingTierConfig threads it through).
DEFAULT_PRIORITY_CLASSES = ("batch", "standard", "interactive")


@dataclass(frozen=True)
class ShedRecord:
    """Why a request was not served (the recorded, auditable drop).

    ``reason`` is ``"deadline"`` (the admission-time completion estimate
    exceeded the request's deadline) or ``"overload"`` (every engine
    queue was full and the request was — or was displaced by — a
    higher-priority arrival).
    """

    request_id: int
    reason: str                    # "deadline" | "overload"
    priority: str
    priority_level: int
    deadline_steps: int | None
    eta_steps: float | None = None  # the estimate that rejected it
    displaced_by: int | None = None  # overload: the admitted higher-prio rid


class SNNServingTier:
    """Front-end router over N same-seed streaming engines (class doc
    above; construction knobs mirror ``SNNServingTierConfig``).

    ``sharded=True`` partitions the visible jax devices into
    ``num_engines`` contiguous slices — each engine becomes a
    ``ShardedSNNStreamEngine`` over its own slice's mesh, i.e. a
    simulated per-host lane mesh (CI runs two 4-device "hosts" on an
    8-device forced-host CPU).  ``shedding=False`` disables both shed
    paths (every request is eventually served — the bit-identity
    property's configuration).
    """

    def __init__(self, params_q: dict, cfg: SNNConfig, *,
                 num_engines: int = 2, lanes_per_engine: int | None = None,
                 chunk_steps: int | None = None, patience: int = 2,
                 seed: int = 0,
                 backend: str | None = None,
                 priority_classes: tuple = DEFAULT_PRIORITY_CLASSES,
                 default_priority: str = "standard",
                 default_deadline_steps: int | None = None,
                 queue_limit: int | None = None, shedding: bool = True,
                 sharded: bool = False,
                 devices_per_engine: int | None = None,
                 adaptive=None,
                 fault_plan: FaultPlan | str | None = None,
                 fault_cfg: FaultToleranceConfig | None = None,
                 ledger=None,
                 dispatch_cache=None):
        if num_engines < 1:
            raise ValueError(f"num_engines must be >= 1, got {num_engines}")
        if default_priority not in priority_classes:
            raise ValueError(f"default priority {default_priority!r} not in "
                             f"{priority_classes}")
        self.priority_classes = tuple(priority_classes)
        self.default_priority = default_priority
        self.default_deadline_steps = default_deadline_steps
        self.queue_limit = queue_limit
        self.shedding = shedding
        self.seed = seed
        if isinstance(fault_plan, str):
            fault_plan = FaultPlan.from_spec(fault_plan)
        self.fault_plan = fault_plan
        self.fault_cfg = fault_cfg or FaultToleranceConfig()

        def _inj(i: int) -> FaultInjector | None:
            # engines built without one still arm from REPRO_FAULT_PLAN
            return (FaultInjector(fault_plan, i)
                    if fault_plan is not None else None)

        self.engines: list[SNNStreamEngine] = []
        if sharded:
            import jax

            from ..distributed.sharding import make_device_mesh
            from .snn_engine import ShardedSNNStreamEngine
            devs = jax.devices()
            per = (devices_per_engine if devices_per_engine is not None
                   else len(devs) // num_engines)
            if per < 1 or per * num_engines > len(devs):
                raise ValueError(
                    f"cannot carve {num_engines} × {per}-device hosts out "
                    f"of {len(devs)} visible devices")
            for i in range(num_engines):
                mesh = make_device_mesh(
                    (per,), ("data",), devices=devs[i * per:(i + 1) * per])
                self.engines.append(ShardedSNNStreamEngine(
                    params_q, cfg, mesh=mesh,
                    batch_size=lanes_per_engine, chunk_steps=chunk_steps,
                    patience=patience, seed=seed, backend=backend,
                    adaptive=adaptive, engine_id=i, injector=_inj(i),
                    fault_cfg=self.fault_cfg, dispatch_cache=dispatch_cache))
        else:
            for i in range(num_engines):
                self.engines.append(SNNStreamEngine(
                    params_q, cfg, batch_size=lanes_per_engine,
                    chunk_steps=chunk_steps, patience=patience, seed=seed,
                    backend=backend, adaptive=adaptive, engine_id=i,
                    injector=_inj(i), fault_cfg=self.fault_cfg,
                    dispatch_cache=dispatch_cache))
        # Optional write-ahead accounting ledger (serve.ledger.Ledger):
        # every terminal record — shed, fault, result — is appended as a
        # JSON line the moment the tier commits to it, so a crash of the
        # hosting process never loses the partition proof.  The cluster
        # coordinator passes one per host; standalone tiers run without.
        self.ledger = ledger
        self._ledgered: set[int] = set()   # rids with a result line on disk
        self.shed: dict[int, ShedRecord] = {}
        self.faulted: dict[int, FaultRecord] = {}
        self._dead: set[int] = set()             # failed engine indices
        self._rid_faults: dict[int, int] = {}    # rid -> faults across engines
        # Host copies of every published weight-plane set, by version —
        # the failover path re-installs a gc'd version on an adopting
        # engine from here (WeightBank.ensure), so an evacuated lane
        # always finishes on its admission-time weights.
        self._version_planes: dict[int, tuple] = {
            0: tuple(layer["w_q"] for layer in params_q["layers"])}
        self._assignment: dict[int, int] = {}    # rid -> engine index
        self._meta: dict[int, tuple] = {}        # rid -> (level, prio, ddl)
        self._next_id = 0
        self.stats = {"routed_per_engine": [0] * num_engines,
                      "shed_deadline": 0, "shed_overload": 0,
                      "displaced": 0, "engines_failed": 0, "evacuated": 0,
                      "requeued": 0, "poison_retries": 0, "quarantined": 0}

    @property
    def cache_decisions(self) -> list:
        """Per-engine dispatch-cache startup decisions (hit/miss, key,
        reason) — the recorded answer to "is this fleet actually serving
        tuned shapes?"."""
        return [e.cache_decision for e in self.engines]

    # ---- routing --------------------------------------------------------
    def _alive(self) -> list[int]:
        return [i for i in range(len(self.engines)) if i not in self._dead]

    def _route_index(self, exclude: int | None = None) -> int:
        """Least-loaded healthy engine; ties break on the lowest index
        (the deterministic spray order the reproducibility tests replay).
        The health surface rides the same score — a degraded engine bids
        high, a dead one infinite.  ``exclude`` steers a poison-request
        retry away from the engine it just faulted on (when another
        healthy engine exists)."""
        idxs = self._alive()
        if exclude is not None and len(idxs) > 1:
            idxs = [i for i in idxs if i != exclude]
        scores = [(load_score(self.engines[i].load_summary()), i)
                  for i in idxs]
        return min(scores)[1]

    def _level(self, priority: str) -> int:
        try:
            return self.priority_classes.index(priority)
        except ValueError:
            raise ValueError(f"unknown priority class {priority!r}: tier "
                             f"serves {self.priority_classes}") from None

    def _shed(self, rid: int, reason: str, priority: str, level: int,
              deadline: int | None, *, eta: float | None = None,
              displaced_by: int | None = None) -> None:
        self.shed[rid] = ShedRecord(
            request_id=rid, reason=reason, priority=priority,
            priority_level=level, deadline_steps=deadline, eta_steps=eta,
            displaced_by=displaced_by)
        self.stats[f"shed_{reason}"] += 1
        if self.ledger is not None:
            self.ledger.append({"kind": "shed", "rid": rid,
                                **asdict(self.shed[rid])})

    def _overload_victim(self) -> int | None:
        """The queued request overload shedding would displace: lowest
        priority class first, newest arrival within the class (its wait
        so far is the smallest sunk cost).  None if any queue has room."""
        if self.queue_limit is None:
            return None
        alive = [self.engines[i] for i in self._alive()]
        if any(len(e.queue) < self.queue_limit for e in alive):
            return None
        queued = [rid for e in alive for rid, _ in e.queue]
        if not queued:
            return None
        return max(queued, key=lambda r: (-self._meta[r][0], r))

    def _evict(self, victim: int) -> int:
        """Remove a queued request from its engine; returns the engine."""
        idx = self._assignment.pop(victim)
        eng = self.engines[idx]
        eng.queue = [q for q in eng.queue if q[0] != victim]
        self.stats["routed_per_engine"][idx] -= 1
        return idx

    # ---- intake ---------------------------------------------------------
    def submit(self, pixels_u8, *, priority: str | None = None,
               deadline_steps: int | None = None,
               request_id: int | None = None) -> int:
        """Admit (or shed) one request; returns its tier-global id.

        Admission runs entirely at submit time — shed decisions are never
        deferred to a queue scan, so a caller learns a request's fate
        (``rid in tier.shed``) as soon as the tier does.

        All validation (priority class, ``request_id`` collision) runs
        BEFORE any tier state is touched: a rejected submit leaves the
        tier exactly as it found it — no id consumed, no bookkeeping
        entry, no queue mutation (regression-tested; the id counter used
        to advance before the priority check could throw).
        """
        priority = self.default_priority if priority is None else priority
        level = self._level(priority)
        if request_id is None:
            rid = self._next_id
        else:
            rid = int(request_id)
            if rid in self._meta:
                raise ValueError(f"request id {rid} already in use")
        deadline = (self.default_deadline_steps if deadline_steps is None
                    else deadline_steps)
        self._next_id = max(self._next_id, rid + 1)
        self._meta[rid] = (level, priority, deadline)
        if not self._alive():
            # every engine is dead: recorded, never silent
            self._drop(rid, "no_capacity", None)
            return rid
        if not self.shedding:
            self._admit(rid, pixels_u8, self._route_index())
            return rid
        # overload first: a doomed-by-deadline request must not displace a
        # queued one
        victim = self._overload_victim()
        if victim is not None:
            if level <= self._meta[victim][0]:
                # nothing queued is lower-priority than the arrival
                self._shed(rid, "overload", priority, level, deadline)
                return rid
        idx = (self._route_index() if victim is None else None)
        eta = estimate_eta_steps(
            self.engines[idx if idx is not None
                         else self._assignment[victim]].load_summary())
        if deadline is not None and eta > deadline:
            self._shed(rid, "deadline", priority, level, deadline, eta=eta)
            return rid
        if victim is not None:
            vl, vp, vd = self._meta[victim]
            self._shed(victim, "overload", vp, vl, vd, displaced_by=rid)
            idx = self._evict(victim)
            self.stats["displaced"] += 1
        self._admit(rid, pixels_u8, idx)
        return rid

    def _admit(self, rid: int, pixels_u8, idx: int) -> None:
        self.engines[idx].submit(pixels_u8, request_id=rid)
        self._assignment[rid] = idx
        self.stats["routed_per_engine"][idx] += 1

    # ---- failover (serve.faults) ----------------------------------------
    def _drop(self, rid: int, reason: str, engine: int | None,
              detail: str = "") -> None:
        """Record an unrecoverable request — the never-silent fault drop."""
        self._assignment.pop(rid, None)
        self.faulted[rid] = FaultRecord(
            request_id=rid, reason=reason, engine=engine,
            faults=self._rid_faults.get(rid, 0),
            replay_seed=self.seed + rid, detail=detail)
        if reason == "quarantined":
            self.stats["quarantined"] += 1
        if self.ledger is not None:
            self.ledger.append({"kind": "fault", "rid": rid,
                                **asdict(self.faulted[rid])})

    def _adopt_row(self, tgt: int, rid: int, row) -> None:
        """Re-admit one evacuated lane row onto engine ``tgt``, restoring
        its (possibly garbage-collected) weight version first."""
        eng = self.engines[tgt]
        v = int(row.weight_version)
        if v not in eng.bank.versions:
            eng.bank.ensure(v, eng._place_weights(self._version_planes[v]))
        eng.adopt(rid, row)
        self._assignment[rid] = tgt

    def _handle_poison(self, idx: int, fault: PoisonDispatchError) -> None:
        """Evict the poison request's lane; retry elsewhere or quarantine.

        The lane row is evacuated bit-exactly, so if the fault was
        engine-local (or transient) the retried window still resumes
        bit-identically.  After ``fault_cfg.quarantine_after`` faults
        across engines the request is quarantined with its replay seed
        (``FaultRecord``) instead of being retried forever.
        """
        rid = fault.request_id
        row = self.engines[idx].evict_lane(rid)
        self._rid_faults[rid] = self._rid_faults.get(rid, 0) + 1
        if self._rid_faults[rid] >= self.fault_cfg.quarantine_after:
            self._drop(rid, "quarantined", idx, detail=str(fault))
            return
        self._adopt_row(self._route_index(exclude=idx), rid, row)
        self.stats["poison_retries"] += 1

    def _handle_engine_failure(self, idx: int, fault: EngineFailure) -> None:
        """Failover: mark the engine dead, evacuate its lanes, re-route
        its queue, and record what could not be recovered.

        The failed engine's in-flight lanes are snapshotted at their last
        committed chunk boundary (the injector faults *before* a launch,
        and a hung launch makes no progress, so the device tile is always
        valid pre-fault state) and re-admitted least-loaded onto healthy
        engines — resuming bit-identically mid-window.  ``state_lost``
        failures (device gone with its memory) shed every in-flight lane
        as a ``FaultRecord`` instead; the host queue and pending
        adoptions are host-side and always recoverable.  The dead
        engine's draining weight versions are freed (``bank.abort``) —
        its lanes now live elsewhere, restored via the tier's host
        copies.
        """
        eng = self.engines[idx]
        self._dead.add(idx)
        self.stats["engines_failed"] += 1
        queued = list(eng.queue)
        eng.queue.clear()
        adoptions = list(eng._adoptions)
        eng._adoptions.clear()
        if fault.state_lost:
            rows = []
            lost = [r for r in eng.lane_req if r is not None]
            eng.lane_req = [None] * eng.batch_size
        else:
            rows = eng.snapshot_lanes()
            lost = []
        eng.bank.abort()
        for rid in lost:
            self._drop(rid, "state_lost", idx, detail=str(fault))
        for rid, row in rows + adoptions:
            if not self._alive():
                self._drop(rid, "engine_lost", idx, detail=str(fault))
                continue
            self._adopt_row(self._route_index(), rid, row)
            self.stats["evacuated"] += 1
        for rid, px in queued:
            if not self._alive():
                self._drop(rid, "engine_lost", idx, detail=str(fault))
                continue
            tgt = self._route_index()
            self.engines[tgt].submit(px, request_id=rid)
            self._assignment[rid] = tgt
            self.stats["requeued"] += 1

    def _ledger_results(self, rids) -> None:
        """Replicate finished results to the host ledger (exactly once).

        A result computed but not yet acknowledged upstream must survive
        the hosting process dying: the line lands on disk the round the
        lane retires, before anything else consumes it.  No-op without a
        ledger; ``_ledgered`` makes re-harvests idempotent.
        """
        if self.ledger is None:
            return
        from .wire import result_to_wire
        for rid in rids:
            if rid in self._ledgered:
                continue
            for e in self.engines:
                if rid in e.results:
                    self.ledger.append({"kind": "result", "rid": rid,
                                        **result_to_wire(e.results[rid])})
                    self._ledgered.add(rid)
                    break

    # ---- drive ----------------------------------------------------------
    @property
    def pending(self) -> int:
        return sum(self.engines[i].pending for i in self._alive())

    def step(self) -> list[int]:
        """One chunk on every healthy engine with work; returns finished
        rids.  Engine faults surface here as typed exceptions and are
        handled inline — an engine failing mid-round hands its work to
        the engines after it in the same round."""
        done = []
        for idx, e in enumerate(self.engines):
            if idx in self._dead or not e.pending:
                continue
            try:
                done.extend(e.step())
            except PoisonDispatchError as f:
                self._handle_poison(idx, f)
            except EngineFailure as f:
                self._handle_engine_failure(idx, f)
        self._ledger_results(done)
        return done

    def run(self, max_chunks: int | None = None) -> dict[int, RequestResult]:
        """Drive all engines until every admitted request has a result.

        Engines advance in lockstep rounds (one chunk each per round) —
        the in-process stand-in for N hosts running concurrently.  Shed
        requests are *not* in the returned dict; they are in
        :attr:`shed`, and fault casualties in :attr:`faulted` — the three
        together partition every submitted id.
        """
        limit = max_chunks if max_chunks is not None else sum(
            (e.pending + e.batch_size)
            * (e.cfg.num_steps // max(1, e.controller.min_chunk_steps) + 2)
            for e in self.engines) + (
                64 * len(self.engines)
                if any(e.injector is not None for e in self.engines) else 0)
        for _ in range(limit):
            if self.pending == 0:
                break
            self.step()
        for i in self._alive():
            self.engines[i].run(max_chunks=0)  # final harvest
        self._ledger_results(list(self.results))
        return self.results

    @property
    def results(self) -> dict[int, RequestResult]:
        out: dict[int, RequestResult] = {}
        for e in self.engines:
            out.update(e.results)
        return out

    def load_report(self) -> list:
        """Per-engine ``EngineLoad`` snapshot (ordered by engine index)."""
        return [e.load_summary() for e in self.engines]

    # ---- weight rollout -------------------------------------------------
    def begin_rollout(self, params_q: dict) -> int:
        """Broadcast new packed weight planes to every engine, zero-drain.

        Returns the fleet-wide new version (healthy engines move in
        lockstep — they were constructed together and roll together;
        dead engines are skipped, their drained versions already
        aborted).  Completion is per-engine as its last old-version lane
        retires; :attr:`rollout_active` goes False when the whole healthy
        fleet finished — including lanes evacuated onto engines that had
        already dropped the old version (restored via ``bank.ensure``),
        which is why a rollout can never complete while an old-version
        lane sits anywhere alive.
        """
        versions = {self.engines[i].begin_rollout(params_q)
                    for i in self._alive()}
        assert len(versions) == 1, f"engines out of lockstep: {versions}"
        v = versions.pop()
        self._version_planes[v] = tuple(
            layer["w_q"] for layer in params_q["layers"])
        return v

    @property
    def rollout_active(self) -> bool:
        return any(self.engines[i].bank.rolling for i in self._alive())

    def rollout_history(self) -> list:
        """Per-engine rollout event logs (ordered by engine index)."""
        return [list(e.bank.history) for e in self.engines]
